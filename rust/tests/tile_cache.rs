//! Integration: the cross-request hot-tile cache — CPU serving with the
//! cache on vs off vs the serial reference must be bitwise-identical
//! across channel counts and steal interleavings; an epoch bump (plan
//! rebuild) must never serve a stale tile; and the per-worker LRU must be
//! observable through the server's metrics.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use tlv_hgnn::coordinator::{PlanCache, Server, ServerConfig};
use tlv_hgnn::engine::{
    ApproxScores, EngineMode, ErrorReport, FeatureState, FusedEngine, InferencePlan, PruneBudget,
    ReferenceEngine, TileCache, TileScratch,
};
use tlv_hgnn::hetgraph::{HetGraph, HetGraphBuilder, VId};
use tlv_hgnn::model::{ModelConfig, ModelKind};
use tlv_hgnn::util::prop::{check, gen};
use tlv_hgnn::util::SmallRng;

fn graph(seed: u64) -> HetGraph {
    let mut b = HetGraphBuilder::new("tile-cache-e2e");
    let p = b.add_vertex_type("P", 100, 64);
    let a = b.add_vertex_type("A", 150, 64);
    let s0 = b.add_semantic("AP", a, p);
    let s1 = b.add_semantic("PP", p, p);
    b.set_target_type(p);
    let mut rng = SmallRng::seed_from_u64(seed);
    for t in 0..100u32 {
        for _ in 0..rng.gen_range(10) {
            b.add_edge(VId(100 + rng.gen_range(150) as u32), VId(t), s0);
        }
        for _ in 0..rng.gen_range(4) {
            let s = rng.gen_range(100) as u32;
            if s != t {
                b.add_edge(VId(s), VId(t), s1);
            }
        }
    }
    b.build().unwrap()
}

fn cpu_config(kind: ModelKind, channels: usize, cache_bytes: usize) -> ServerConfig {
    ServerConfig { channels, tile_cache_bytes: cache_bytes, ..ServerConfig::cpu(kind) }
}

#[test]
fn cache_on_off_reference_bitwise_across_channels() {
    // The tentpole invariant: for every model and channel count, serving
    // with the cache enabled is bitwise-identical to serving with it
    // disabled AND to the serial reference oracle — on cold misses and on
    // warm hits alike (requests repeat so the warm path actually runs).
    let g = Arc::new(graph(11));
    let targets: Vec<VId> = (0..100).map(VId).collect();
    for kind in ModelKind::ALL {
        let reference = ReferenceEngine::new(&g, ModelConfig::new(kind), 64);
        let want = reference.embed_semantics_complete(&targets);
        for channels in [1usize, 2, 8] {
            let on = Server::start(Arc::clone(&g), cpu_config(kind, channels, 32 << 20)).unwrap();
            let off = Server::start(Arc::clone(&g), cpu_config(kind, channels, 0)).unwrap();
            for round in 0..3 {
                for server in [&on, &off] {
                    let resp = server.submit(targets.clone()).unwrap();
                    assert_eq!(resp.embeddings.len(), targets.len());
                    for (i, &t) in targets.iter().enumerate() {
                        let got = resp.embedding_of(t).expect("missing row");
                        assert_eq!(
                            got,
                            want.row(i),
                            "{kind:?} ch={channels} round={round} target {t} not bitwise"
                        );
                    }
                }
            }
            assert_eq!(
                off.metrics.tile_hits.load(Ordering::Relaxed)
                    + off.metrics.tile_misses.load(Ordering::Relaxed),
                0,
                "cache-off server must never touch a tile cache"
            );
            if channels == 1 {
                // One channel → no stealing → every repeat after the cold
                // round must hit (deterministically).
                assert!(
                    on.metrics.tile_hits.load(Ordering::Relaxed) >= 2,
                    "single-channel repeats must hit the tile cache"
                );
                assert!(on.metrics.tile_gather_bytes_saved.load(Ordering::Relaxed) > 0);
            }
            on.shutdown();
            off.shutdown();
        }
    }
}

#[test]
fn steal_interleavings_stay_bitwise_with_cache_on() {
    // Concurrent submitters force work stealing; stolen items bypass the
    // thief's cache (slow path) while affinity-placed repeats hit. Any
    // interleaving must produce reference bits.
    let g = Arc::new(graph(19));
    let server =
        Arc::new(Server::start(Arc::clone(&g), cpu_config(ModelKind::Rgat, 4, 32 << 20)).unwrap());
    let reference = ReferenceEngine::new(&g, ModelConfig::new(ModelKind::Rgat), 64);
    let targets: Vec<VId> = (0..100).map(VId).collect();
    let want = reference.embed_semantics_complete(&targets);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let server = Arc::clone(&server);
            let targets = targets.clone();
            let want = &want;
            s.spawn(move || {
                for _ in 0..3 {
                    let resp = server.submit(targets.clone()).unwrap();
                    for (i, &t) in targets.iter().enumerate() {
                        let got = resp.embedding_of(t).expect("missing row");
                        assert_eq!(got, want.row(i), "target {t} not bitwise under contention");
                    }
                }
            });
        }
    });
    let m = &server.metrics;
    let executions = m.tile_hits.load(Ordering::Relaxed)
        + m.tile_misses.load(Ordering::Relaxed)
        + m.tile_bypass.load(Ordering::Relaxed);
    // Every routed part of every request went through exactly one of the
    // three paths (hit / miss / steal-bypass).
    assert_eq!(executions, m.blocks_executed.load(Ordering::Relaxed));
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("server still shared"),
    }
}

#[test]
fn epoch_bump_never_serves_a_stale_tile() {
    // Property, over random graphs: fill a cache under (plan, state) A;
    // rebuild the plan (PlanCache::invalidate → strictly larger epoch) and
    // move to a different feature state B; after TileCache::set_epoch the
    // same request must MISS and recompute B's bits exactly. The same
    // request without the bump hits, so the test is non-vacuous.
    check("epoch-bump-never-stale", 8, |rng| {
        let g = Arc::new(gen::hetgraph(rng));
        let order = g.target_vertices();
        let targets: Vec<VId> = order.iter().copied().take(12).collect();
        assert!(!targets.is_empty());
        let plans = PlanCache::new();
        let m = ModelConfig::new(ModelKind::Rgcn);
        let (plan, epoch) = plans.get_or_build_epoch(&g, m.clone(), 24);
        let state = FeatureState::project_all(&plan, 1);
        let engine = FusedEngine::over(&plan, &state);
        let mut cache = TileCache::new(16 << 20, epoch);
        let mut scratch = TileScratch::default();

        let (cold, _, o_cold) = engine.embed_group_tile_cached(&targets, &mut cache, &mut scratch);
        assert!(!o_cold.hit);
        let (warm, _, o_warm) = engine.embed_group_tile_cached(&targets, &mut cache, &mut scratch);
        assert!(o_warm.hit, "same epoch, same request: must hit");
        assert_eq!(cold.max_abs_diff(&warm), 0.0);

        // Layer-2 feature state: same plan shape, different projected rows
        // — exactly what a stale tile would silently corrupt.
        let full = engine.embed_semantics_complete(&order, 1);
        let mut state2 = state.clone();
        state2.reseed(&order, &full);

        plans.invalidate(&g);
        let (plan2, epoch2) = plans.get_or_build_epoch(&g, m.clone(), 24);
        assert!(epoch2 > epoch, "rebuild must advance the epoch");
        cache.set_epoch(epoch2);

        let engine2 = FusedEngine::over(&plan2, &state2);
        let hits_before = cache.stats.hits;
        let (got, _, o2) = engine2.embed_group_tile_cached(&targets, &mut cache, &mut scratch);
        assert!(!o2.hit, "post-bump request must miss");
        assert_eq!(cache.stats.hits, hits_before, "no stale tile may be served");
        let (want, _) = engine2.embed_group_tile(&targets);
        assert_eq!(want.max_abs_diff(&got), 0.0, "post-bump bits must be fresh");
    });
}

#[test]
fn exact_and_pruned_tiles_never_collide_in_one_cache() {
    // PR 10 regression: the engine mode is part of the tile-cache key. The
    // same target set materialized under `Exact` and `Approximate(ε)`
    // occupies two distinct entries — a cross-mode lookup degrades to a
    // miss (recompute), never to a wrong row — and distinct budgets are
    // likewise distinct keys.
    let g = Arc::new(graph(31));
    let targets: Vec<VId> = (0..100).map(VId).collect();
    let m = ModelConfig::new(ModelKind::Rgat);
    let plan = InferencePlan::build(&g, m.clone(), 64);
    let state = FeatureState::project_all(&plan, 1);
    let engine = FusedEngine::over(&plan, &state);
    let scores = ApproxScores::build(&plan, &state);
    let budget = PruneBudget::new(0.2).unwrap();
    let pruned = EngineMode::Approximate(budget);
    let want = ReferenceEngine::new(&g, m, 64).embed_semantics_complete(&targets);
    let mut cache = TileCache::new(32 << 20, 0);
    let mut scratch = TileScratch::default();
    let mut run = |mode: EngineMode, s: Option<&ApproxScores>| {
        engine.embed_group_tile_cached_mode(&targets, mode, s, &mut cache, &mut scratch)
    };

    // Pruned admission first...
    let (approx_cold, _, oa) = run(pruned, Some(&scores));
    assert!(!oa.hit);
    // ...then the same targets exactly: must MISS (distinct key) and
    // produce reference bits — a pruned tile can never answer it.
    let (exact_cold, _, oe) = run(EngineMode::Exact, None);
    assert!(!oe.hit, "an exact lookup must never hit a pruned tile");
    assert_eq!(want.max_abs_diff(&exact_cold), 0.0, "exact bits after a pruned admission");
    // Both entries now coexist: each mode hits its own and replays its own
    // bits, so the exact admission did not clobber the pruned entry.
    let (exact_warm, _, oe2) = run(EngineMode::Exact, None);
    assert!(oe2.hit, "exact entry must hit on repeat");
    assert_eq!(exact_cold.max_abs_diff(&exact_warm), 0.0);
    let (approx_warm, _, oa2) = run(pruned, Some(&scores));
    assert!(oa2.hit, "pruned entry must survive the exact admission");
    assert_eq!(approx_cold.max_abs_diff(&approx_warm), 0.0, "pruned hit must replay bitwise");
    // A different budget is a different key.
    let other = EngineMode::Approximate(PruneBudget::new(0.01).unwrap());
    let (_, _, ob) = run(other, Some(&scores));
    assert!(!ob.hit, "a different budget must be a different key");
    // And the pruned rows obeyed the budget throughout.
    let report = ErrorReport::compare(budget, &approx_cold, &want);
    assert!(report.within_budget(), "{}", report.summary());
}

#[test]
fn shared_plan_cache_tags_every_server_with_its_epoch() {
    // Two servers resolving the same (graph, model, dims) through one
    // PlanCache share one plan and one epoch; their repeated traffic hits
    // independently (per-worker caches are private).
    let g = Arc::new(graph(23));
    let plans = Arc::new(PlanCache::new());
    let mk = || ServerConfig {
        channels: 1,
        plans: Arc::clone(&plans),
        ..ServerConfig::cpu(ModelKind::Rgcn)
    };
    let a = Server::start(Arc::clone(&g), mk()).unwrap();
    let b = Server::start(Arc::clone(&g), mk()).unwrap();
    assert_eq!(plans.len(), 1, "both servers share one cached plan");
    let targets: Vec<VId> = (0..50).map(VId).collect();
    for server in [&a, &b] {
        for _ in 0..2 {
            server.submit(targets.clone()).unwrap();
        }
        assert!(server.metrics.tile_hits.load(Ordering::Relaxed) >= 1);
    }
    a.shutdown();
    b.shutdown();
}
