//! Integration + property suite for the opt-in approximate mode
//! (`engine::approx`): attention-disparity pruned aggregation behind an
//! error-bound verification harness.
//!
//! The harness is the point — the pruned path ships only because every
//! claim below is machine-checked against the serial `ReferenceEngine`:
//!
//! * **Error within budget.** On random heterogeneous graphs, across
//!   budgets and thread counts, every target row's relative L2 error vs
//!   the exact oracle stays within the per-vertex budget ε.
//! * **ε = 0 collapses to bitwise-exact.** A zero budget prunes nothing
//!   and reproduces the exact bits, edge for edge.
//! * **Monotone nesting.** A tighter budget's dropped neighbor set is a
//!   subset of a looser budget's — tightening can never increase error.
//! * **Determinism.** The pruned neighbor selection and the output bits
//!   are identical across runs and thread counts.
//! * **Exact-mode regression wall.** With the mode enum plumbed through
//!   engine, tile cache, and server, every pre-existing exact path is
//!   bitwise-untouched, and an exact server refuses approximate requests
//!   with a typed error.

use std::sync::Arc;
use tlv_hgnn::coordinator::{ServeError, Server, ServerConfig};
use tlv_hgnn::datasets::Dataset;
use tlv_hgnn::engine::{
    ApproxScores, EngineMode, ErrorReport, FeatureState, FusedEngine, InferencePlan, PruneBudget,
    ReferenceEngine, TileCache, TileScratch,
};
use tlv_hgnn::hetgraph::{GraphDelta, VId};
use tlv_hgnn::model::{ModelConfig, ModelKind};
use tlv_hgnn::util::prop::{check, gen};

/// Relative L2 error of one served row against the oracle row (the same
/// definition `ErrorReport` uses, f64 accumulation).
fn rel_l2(got: &[f32], want: &[f32]) -> f64 {
    assert_eq!(got.len(), want.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in got.iter().zip(want) {
        let d = f64::from(*a) - f64::from(*b);
        num += d * d;
        den += f64::from(*b) * f64::from(*b);
    }
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (num / den).sqrt()
    }
}

#[test]
fn prop_error_stays_within_budget_on_random_graphs() {
    // The headline property: random graph x budget x thread count, every
    // row within its per-vertex budget against the serial oracle.
    check("approx-error-within-budget", 10, |rng| {
        let g = gen::hetgraph(rng);
        let order = g.target_vertices();
        let kind = [ModelKind::Rgat, ModelKind::Rgcn, ModelKind::Nars][rng.gen_index(3)];
        let plan = InferencePlan::build(&g, ModelConfig::new(kind), 16);
        let state = FeatureState::project_all(&plan, 1);
        let engine = FusedEngine::over(&plan, &state);
        let scores = ApproxScores::build(&plan, &state);
        let exact =
            ReferenceEngine::new(&g, ModelConfig::new(kind), 16).embed_semantics_complete(&order);
        for eps in [0.005, 0.02, 0.1] {
            let budget = PruneBudget::new(eps).unwrap();
            for threads in [1usize, 2, 8] {
                let (approx, stats) = engine.embed_approximate(&order, threads, budget, &scores);
                let report = ErrorReport::compare(budget, &approx, &exact);
                assert!(
                    report.within_budget(),
                    "{kind:?} eps={eps} t={threads}: {}",
                    report.summary()
                );
                assert_eq!(report.rows, order.len());
                assert!(stats.kept_edges <= stats.total_edges);
            }
        }
    });
}

#[test]
fn prop_zero_budget_is_bitwise_exact() {
    // ε = 0 must not be "approximately exact": it keeps every edge and
    // reproduces the reference bits, at any thread count.
    check("approx-zero-budget-bitwise", 8, |rng| {
        let g = gen::hetgraph(rng);
        let order = g.target_vertices();
        let kind = [ModelKind::Rgat, ModelKind::Rgcn, ModelKind::Nars][rng.gen_index(3)];
        let plan = InferencePlan::build(&g, ModelConfig::new(kind), 16);
        let state = FeatureState::project_all(&plan, 1);
        let engine = FusedEngine::over(&plan, &state);
        let scores = ApproxScores::build(&plan, &state);
        let want =
            ReferenceEngine::new(&g, ModelConfig::new(kind), 16).embed_semantics_complete(&order);
        for threads in [1usize, 3] {
            let (out, stats) =
                engine.embed_approximate(&order, threads, PruneBudget::zero(), &scores);
            assert_eq!(want.max_abs_diff(&out), 0.0, "{kind:?} t={threads}: ε=0 not bitwise");
            assert_eq!(stats.kept_edges, stats.total_edges, "ε=0 must prune nothing");
            assert_eq!(stats.fallbacks, 0, "nothing pruned, nothing to guard");
        }
    });
}

#[test]
fn prop_selection_is_deterministic_and_nests_across_budgets() {
    // Selection-level monotonicity: over one fixed ranking the drop
    // threshold is linear in ε, so a tighter budget's dropped set must be
    // a subset of a looser budget's — and every selection must replay
    // identically (it is a pure function of (plan, scores, target, ε)).
    check("approx-selection-nesting", 10, |rng| {
        let g = gen::hetgraph(rng);
        let order = g.target_vertices();
        let plan = InferencePlan::build(&g, ModelConfig::new(ModelKind::Rgat), 16);
        let state = FeatureState::project_all(&plan, 1);
        let scores = ApproxScores::build(&plan, &state);
        for &t in order.iter().take(24) {
            assert!(
                scores.dropped_positions(&plan, t, 0.0).is_empty(),
                "ε=0 must drop nothing at {t}"
            );
            let mut prev: Vec<usize> = Vec::new();
            for eps in [0.002, 0.01, 0.05, 0.2] {
                let dropped = scores.dropped_positions(&plan, t, eps);
                assert_eq!(
                    dropped,
                    scores.dropped_positions(&plan, t, eps),
                    "selection must replay identically at {t} eps={eps}"
                );
                assert!(
                    prev.iter().all(|p| dropped.contains(p)),
                    "tighter budget dropped a neighbor the looser one kept at {t} eps={eps}"
                );
                prev = dropped;
            }
        }
    });
}

#[test]
fn approx_output_is_bitwise_deterministic_across_runs_and_threads() {
    // Per-target selection and arithmetic are independent of striping, so
    // the approximate output (unlike its error, which only has to stay
    // within budget) is itself bitwise-reproducible at any parallelism.
    let g = Dataset::Acm.load(0.04);
    let order = g.target_vertices();
    let plan = InferencePlan::build(&g, ModelConfig::new(ModelKind::Rgat), 64);
    let state = FeatureState::project_all(&plan, 4);
    let engine = FusedEngine::over(&plan, &state);
    let scores = ApproxScores::build(&plan, &state);
    let budget = PruneBudget::new(0.05).unwrap();
    let (a, sa) = engine.embed_approximate(&order, 1, budget, &scores);
    let (a2, _) = engine.embed_approximate(&order, 1, budget, &scores);
    assert_eq!(a.max_abs_diff(&a2), 0.0, "same thread count must replay bitwise");
    for threads in [2usize, 4, 7] {
        let (b, sb) = engine.embed_approximate(&order, threads, budget, &scores);
        assert_eq!(a.max_abs_diff(&b), 0.0, "thread count {threads} changed approximate bits");
        assert_eq!(sa.kept_edges, sb.kept_edges, "pruned set must not depend on striping");
        assert_eq!(sa.total_edges, sb.total_edges);
        assert_eq!(sa.fallbacks, sb.fallbacks, "guard decisions must not depend on striping");
    }
    // Non-vacuity: a loose budget on the attention model actually prunes.
    let loose = PruneBudget::new(0.2).unwrap();
    let (_, sl) = engine.embed_approximate(&order, 4, loose, &scores);
    assert!(sl.kept_edges < sl.total_edges, "20% budget must drop some attention tail");
}

#[test]
fn exact_mode_regression_wall() {
    // Mode plumbing must leave every pre-existing exact path untouched:
    // striped embed, group-tile embed, and the cached path — both through
    // the legacy exact entry point and through the mode-dispatched one
    // with `EngineMode::Exact` — all bitwise vs the reference.
    assert!(EngineMode::default().is_exact(), "exact must remain the default mode");
    assert_eq!(EngineMode::Exact.budget(), None);
    for d in [Dataset::Acm, Dataset::Imdb] {
        let g = d.load(0.03);
        let order = g.target_vertices();
        for kind in ModelKind::ALL {
            let e = ReferenceEngine::new(&g, ModelConfig::new(kind), 24);
            let f = FusedEngine::new(&e);
            let want = e.embed_semantics_complete(&order);
            for threads in [1usize, 4] {
                let got = f.embed_semantics_complete(&order, threads);
                assert_eq!(
                    want.max_abs_diff(&got),
                    0.0,
                    "{} {kind:?} t={threads}: striped exact path regressed",
                    d.name()
                );
            }
            let (tiled, _) = f.embed_group_tile(&order);
            assert_eq!(
                want.max_abs_diff(&tiled),
                0.0,
                "{} {kind:?}: group-tile exact path regressed",
                d.name()
            );
            let mut cache = TileCache::new(8 << 20, 0);
            let mut scratch = TileScratch::default();
            let (cold, _, o_cold) = f.embed_group_tile_cached(&order, &mut cache, &mut scratch);
            let (warm, _, o_warm) = f.embed_group_tile_cached(&order, &mut cache, &mut scratch);
            assert!(!o_cold.hit && o_warm.hit);
            assert_eq!(want.max_abs_diff(&cold), 0.0, "{} {kind:?}: cached cold", d.name());
            assert_eq!(want.max_abs_diff(&warm), 0.0, "{} {kind:?}: cached warm", d.name());
            // The mode-dispatched entry point with Exact is the identity
            // wrapper — same cache, same bits, still hitting.
            let (via_mode, _, o_mode) = f.embed_group_tile_cached_mode(
                &order,
                EngineMode::Exact,
                None,
                &mut cache,
                &mut scratch,
            );
            assert!(o_mode.hit, "exact mode-dispatched lookup must hit the exact entry");
            assert_eq!(want.max_abs_diff(&via_mode), 0.0, "{} {kind:?}: mode wrapper", d.name());
        }
    }
}

#[test]
fn approximate_server_serves_within_budget_and_replays_bitwise() {
    // End to end: a server built with a budget serves opt-in approximate
    // requests whose rows stay within ε of the oracle — on the cold
    // (miss) round and the warm (cache-hit) round, which must replay the
    // cold rows bitwise. Exact requests on the same server stay bitwise.
    let g = Arc::new(Dataset::Acm.load(0.03));
    let order = g.target_vertices();
    let eps = 0.05;
    let mut cfg = ServerConfig {
        channels: 2,
        tile_cache_bytes: 16 << 20,
        ..ServerConfig::cpu(ModelKind::Rgat)
    };
    cfg.approx = Some(PruneBudget::new(eps).unwrap());
    let server = Server::start(Arc::clone(&g), cfg).unwrap();
    let want = ReferenceEngine::new(&g, ModelConfig::new(ModelKind::Rgat), 64)
        .embed_semantics_complete(&order);

    // Exact traffic on an approximate server: still bitwise.
    let exact_resp = server.submit(order.clone()).unwrap();
    for (i, &t) in order.iter().enumerate() {
        assert_eq!(
            exact_resp.embedding_of(t).expect("missing exact row"),
            want.row(i),
            "exact request on an approximate server must stay bitwise at {t}"
        );
    }

    let mut cold_rows: Vec<Vec<f32>> = Vec::new();
    for round in 0..2 {
        let resp = server.submit_approx(order.clone()).unwrap();
        for (i, &t) in order.iter().enumerate() {
            let got = resp.embedding_of(t).expect("missing approx row");
            let err = rel_l2(got, want.row(i));
            assert!(err <= eps, "round {round} target {t}: rel err {err:.3e} > ε={eps}");
            if round == 0 {
                cold_rows.push(got.to_vec());
            } else {
                assert_eq!(
                    got, &cold_rows[i][..],
                    "warm (cached) round must replay the cold round bitwise at {t}"
                );
            }
        }
    }
    server.shutdown();
}

#[test]
fn approximate_budget_survives_a_live_delta() {
    // A live graph delta republishes plan, state, AND attention scores;
    // post-swap approximate traffic must satisfy the budget against a
    // from-scratch oracle over the mutated graph.
    let g = Arc::new(Dataset::Acm.load(0.03));
    let eps = 0.05;
    let mut cfg = ServerConfig { channels: 2, ..ServerConfig::cpu(ModelKind::Rgat) };
    cfg.approx = Some(PruneBudget::new(eps).unwrap());
    let server = Server::start(Arc::clone(&g), cfg).unwrap();
    let delta = GraphDelta::seeded(&g, 7, 48);
    let swap = server.apply_delta(&delta).unwrap();
    let g2 = swap.graph;
    let order = g2.target_vertices();
    let want = ReferenceEngine::new(&g2, ModelConfig::new(ModelKind::Rgat), 64)
        .embed_semantics_complete(&order);
    let resp = server.submit_approx(order.clone()).unwrap();
    for (i, &t) in order.iter().enumerate() {
        let err = rel_l2(resp.embedding_of(t).expect("missing row"), want.row(i));
        assert!(err <= eps, "post-delta target {t}: rel err {err:.3e} > ε={eps}");
    }
    server.shutdown();
}

#[test]
fn exact_server_refuses_approximate_requests() {
    // Double opt-in: without `ServerConfig::approx` the request flag is a
    // typed, up-front rejection — an exact deployment can never silently
    // serve pruned rows — and the server keeps serving exact afterwards.
    let g = Arc::new(Dataset::Acm.load(0.03));
    let server = Server::start(
        Arc::clone(&g),
        ServerConfig { channels: 1, ..ServerConfig::cpu(ModelKind::Rgcn) },
    )
    .unwrap();
    let targets: Vec<VId> = g.target_vertices().into_iter().take(8).collect();
    let err = server.submit_approx(targets.clone()).unwrap_err();
    assert_eq!(err, ServeError::ApproxUnsupported);
    assert_eq!(err.class(), "approx_unsupported");
    let resp = server.submit(targets.clone()).unwrap();
    assert_eq!(resp.embeddings.len(), targets.len(), "exact service must survive the refusal");
    assert!(server.metrics.summary().contains("approx_rejected=1"));
    server.shutdown();
}
