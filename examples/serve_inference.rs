//! Serving demo: start the coordinator (4 channel workers over PJRT),
//! drive it with concurrent synthetic clients, report latency/throughput
//! and batcher efficiency. Requires `make artifacts`.

use std::sync::Arc;
use std::time::Instant;
use tlv_hgnn::coordinator::{Server, ServerConfig};
use tlv_hgnn::datasets::Dataset;
use tlv_hgnn::hetgraph::VId;
use tlv_hgnn::model::ModelKind;
use tlv_hgnn::runtime::Manifest;
use tlv_hgnn::util::SmallRng;

fn main() -> anyhow::Result<()> {
    if Manifest::load(&Manifest::default_dir()).is_err() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    // ACM at reduced scale: the serving path computes real numerics per
    // vertex, so this sizes the demo for seconds, not minutes.
    let g = Arc::new(Dataset::Acm.load(0.25));
    let targets: Vec<VId> = g.target_vertices();
    println!("graph: {} vertices, {} edges, {} targets", g.num_vertices(), g.num_edges(), targets.len());

    let t0 = Instant::now();
    let server = Arc::new(Server::start(Arc::clone(&g), ServerConfig::new(ModelKind::Rgcn))?);
    println!("server up in {:.2?} (includes FP pass + grouping + 4 workers)\n", t0.elapsed());

    // 8 concurrent clients, 25 requests each, 16 targets per request.
    const CLIENTS: usize = 8;
    const REQS: usize = 25;
    const REQ_TARGETS: usize = 16;
    let t1 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let server = Arc::clone(&server);
        let targets = targets.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = SmallRng::seed_from_u64(c as u64);
            for _ in 0..REQS {
                let req: Vec<VId> =
                    (0..REQ_TARGETS).map(|_| targets[rng.gen_index(targets.len())]).collect();
                let resp = server.submit(req).expect("request failed");
                assert_eq!(resp.embeddings.len(), REQ_TARGETS);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t1.elapsed();

    let total_reqs = (CLIENTS * REQS) as f64;
    let total_targets = total_reqs * REQ_TARGETS as f64;
    let (p50, p95, p99) = server.metrics.latency_percentiles();
    println!("served {total_reqs} requests / {total_targets} embeddings in {wall:.2?}");
    println!("  throughput   {:.0} embeddings/s", total_targets / wall.as_secs_f64());
    println!("  latency      p50={p50}us p95={p95}us p99={p99}us");
    println!("  batching     {:.1}% padded slots", server.metrics.padding_fraction(32) * 100.0);
    println!("  {}", server.metrics.summary());
    Ok(())
}
