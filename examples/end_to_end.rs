//! END-TO-END driver (required by DESIGN.md): exercises the full stack on
//! a real small workload, proving all layers compose —
//!
//!   L1 Pallas kernels -> L2 JAX block model -> AOT HLO artifacts ->
//!   L3 Rust: PJRT runtime + grouping router + batching coordinator,
//!   cross-validated against the CPU reference engine, then the same
//!   workload is run through the cycle simulator and baseline models to
//!   produce the paper-metric table.
//!
//! With `make artifacts` built, the serving path runs through PJRT and is
//! validated within float tolerance. Without artifacts (e.g. CI), the
//! coordinator falls back to the in-process CPU fused engine
//! (`ExecutorKind::Cpu` — group-affinity routing + group-local tiles) and
//! is held to **bitwise** equality, so the example is a complete smoke
//! test on any host. Results recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;
use std::time::Instant;
use tlv_hgnn::baselines::{run_a100, run_hihgnn, GpuConfig, HiHgnnConfig};
use tlv_hgnn::coordinator::{Server, ServerConfig};
use tlv_hgnn::datasets::Dataset;
use tlv_hgnn::energy::{tlv_energy, EnergyTable};
use tlv_hgnn::engine::{FeatureState, FusedEngine, InferencePlan, ReferenceEngine};
use tlv_hgnn::hetgraph::VId;
use tlv_hgnn::model::{ModelConfig, ModelKind};
use tlv_hgnn::runtime::{Manifest, PjrtRuntime};
use tlv_hgnn::sim::{AccelConfig, ExecMode, Simulator};
use tlv_hgnn::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    let have_artifacts =
        Manifest::load(&Manifest::default_dir()).is_ok() && PjrtRuntime::cpu().is_ok();

    // A real small workload: ACM at 10% — ~1.1k targets, real numerics.
    let g = Arc::new(Dataset::Acm.load(0.10));
    println!(
        "workload: ACM@0.10 — {} vertices, {} edges, {} semantics, {} targets",
        g.num_vertices(),
        g.num_edges(),
        g.num_semantics(),
        g.target_vertices().len()
    );
    println!(
        "executor: {}\n",
        if have_artifacts {
            "PJRT (AOT artifacts found)"
        } else {
            "CPU fused engine (no artifacts — bitwise serving path)"
        }
    );

    // ---- Serving path: coordinator, PJRT or CPU workers ----
    let cfg = if have_artifacts {
        ServerConfig::new(ModelKind::Rgcn)
    } else {
        ServerConfig::cpu(ModelKind::Rgcn)
    };
    let t0 = Instant::now();
    let server = Server::start(Arc::clone(&g), cfg)?;
    let startup = t0.elapsed();

    let targets: Vec<VId> = g.target_vertices();
    let t1 = Instant::now();
    let mut served = 0usize;
    let mut responses = Vec::new();
    for chunk in targets.chunks(64) {
        let resp = server.submit(chunk.to_vec())?;
        served += resp.embeddings.len();
        responses.push(resp);
    }
    let serve_wall = t1.elapsed();
    let (p50, p95, p99) = server.metrics.latency_percentiles();
    println!("L3 serving: {served} embeddings in {serve_wall:.2?} (startup {startup:.2?})");
    println!("  throughput {:.0} emb/s; latency p50={p50}us p95={p95}us p99={p99}us", served as f64 / serve_wall.as_secs_f64());

    // ---- Numeric validation vs the CPU reference ----
    // PJRT: K-truncation (profile K=16) is the serving-time neighbor
    // sampling; validate exactly on the subset of targets with deg<=K per
    // semantic, within float tolerance. CPU executor: every target, zero
    // tolerance (the fused group-tile path is bitwise-identical).
    // One build-once plan backs the reference oracle here AND the cycle
    // simulator below (one adjacency transpose for the whole example).
    let plan = Arc::new(InferencePlan::build(&g, ModelConfig::new(ModelKind::Rgcn), 64));
    let state = FeatureState::project_all(&plan, FusedEngine::default_threads());
    let reference = ReferenceEngine::with_plan(&g, Arc::clone(&plan), state);
    let (exact, tolerance): (Vec<VId>, f32) = if have_artifacts {
        let k = 16;
        (
            targets
                .iter()
                .copied()
                .filter(|&t| g.csrs.iter().all(|c| c.neighbors(t).len() <= k))
                .collect(),
            5e-4,
        )
    } else {
        (targets.clone(), 0.0)
    };
    let want = reference.embed_semantics_complete(&exact);
    let mut max_diff = 0f32;
    let mut checked = 0usize;
    for (i, &t) in exact.iter().enumerate() {
        for resp in &responses {
            if let Some(got) = resp.embedding_of(t) {
                let d = got
                    .iter()
                    .zip(want.row(i))
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                max_diff = max_diff.max(d);
                checked += 1;
                break;
            }
        }
    }
    println!(
        "  validation: {checked}/{} targets checked, max |diff| = {max_diff:.2e} (bound {tolerance:.0e}) {}",
        exact.len(),
        if max_diff <= tolerance { "(PASS)" } else { "(FAIL)" }
    );
    assert_eq!(checked, exact.len(), "some targets never served");
    assert!(max_diff <= tolerance, "numeric validation failed");

    // ---- Group-affinity engine on the same workload ----
    let grouped = {
        use tlv_hgnn::grouping::{default_n_max, group_overlap_driven, OverlapHypergraph};
        let h = OverlapHypergraph::build(&g, 0.01);
        group_overlap_driven(&h, default_n_max(targets.len(), 4), 4)
    };
    let engine = FusedEngine::over(&plan, reference.state());
    let striped = engine.embed_semantics_complete(&grouped.flat_order(), 4);
    let (_, tiled, reuse) = engine.embed_grouped_with_reuse(&grouped, 4);
    assert_eq!(striped.max_abs_diff(&tiled), 0.0, "group-tile path diverged");
    println!(
        "  group tiles: {:.2}x row reuse over {} groups ({:.1}% of loads absorbed), bitwise OK",
        reuse.reuse_factor(),
        reuse.groups,
        reuse.saved_fraction() * 100.0
    );

    // ---- Paper-metric table on the same workload ----
    let m = ModelConfig::new(ModelKind::Rgcn);
    let cfg = AccelConfig::tlv_default();
    let sim = Simulator::with_plan(cfg.clone(), &g, &plan);
    let tlv = sim.run(ExecMode::OverlapGrouped);
    let tlv_ms = tlv.time_ms(&cfg);
    let gpu = run_a100(&g, &m, &GpuConfig::a100_80g());
    let hi = run_hihgnn(&g, &m, &HiHgnnConfig::paper());
    let e = tlv_energy(&tlv, &cfg, &m, &EnergyTable::default());

    let mut t = Table::new(&["platform", "time_ms", "dram_MB", "speedup_vs"]);
    t.row(&["A100 (model)".into(), f2(gpu.time_ms), f2(gpu.dram_bytes as f64 / 1e6), f2(gpu.time_ms / tlv_ms)]);
    t.row(&["HiHGNN (model)".into(), f2(hi.time_ms), f2(hi.dram_bytes as f64 / 1e6), f2(hi.time_ms / tlv_ms)]);
    t.row(&["TLV-HGNN (sim)".into(), f2(tlv_ms), f2(tlv.dram.bytes as f64 / 1e6), "1.00".into()]);
    println!("\n=== simulated paper metrics on this workload ===\n{}", t.render());
    println!("TLV energy: {:.3} mJ ({:.0}% DRAM)", e.total_mj(), e.dram_fraction() * 100.0);

    server.shutdown();
    println!("\nE2E OK — all layers composed.");
    Ok(())
}
