//! Per-semantic vs semantics-complete, on all five datasets: memory
//! expansion and feature-access redundancy at the trace level (the §III
//! motivation study), then simulated cycles for both paradigms.

use tlv_hgnn::datasets::Dataset;
use tlv_hgnn::engine::{
    walk_per_semantic, walk_semantics_complete, AccessCounter, MemoryTracker,
};
use tlv_hgnn::model::{ModelConfig, ModelKind};
use tlv_hgnn::sim::{AccelConfig, ExecMode, Simulator};
use tlv_hgnn::util::table::{f2, pct, Table};

fn main() {
    let m = ModelConfig::new(ModelKind::Rgcn);
    let mut t = Table::new(&[
        "dataset", "exp_per_sem", "exp_sem_complete", "target_access_saving", "cycles_B", "cycles_S", "speedup",
    ]);
    for d in Dataset::ALL {
        let scale = if d.is_large() { d.bench_scale() * 0.25 } else { d.bench_scale() };
        let g = d.load(scale);
        let init = g.initial_footprint_bytes() as f64;

        let mut ps_mem = MemoryTracker::default();
        let mut ps_acc = AccessCounter::default();
        {
            let mut tee = tlv_hgnn::engine::TeeSink(&mut ps_mem, &mut ps_acc);
            walk_per_semantic(&g, &m, &mut tee);
        }
        let mut sc_mem = MemoryTracker::default();
        let mut sc_acc = AccessCounter::default();
        {
            let order = g.target_vertices();
            let mut tee = tlv_hgnn::engine::TeeSink(&mut sc_mem, &mut sc_acc);
            walk_semantics_complete(&g, &m, &order, &mut tee);
        }

        let cfg = AccelConfig::tlv_default();
        let sim = Simulator::new(cfg, &g, m.clone());
        let b = sim.run(ExecMode::PerSemanticBaseline);
        let s = sim.run(ExecMode::SemanticsComplete);

        t.row(&[
            d.name().into(),
            f2((init + ps_mem.peak_bytes as f64) / init),
            f2((init + sc_mem.peak_bytes as f64) / init),
            pct(1.0 - sc_acc.total as f64 / ps_acc.total as f64),
            b.cycles.to_string(),
            s.cycles.to_string(),
            f2(b.cycles as f64 / s.cycles as f64),
        ]);
    }
    println!("=== Per-semantic (-B) vs semantics-complete (-S) ===");
    println!("{}", t.render());
}
