//! Explore the overlap-driven vertex grouping: hypergraph statistics,
//! grouping quality vs the random baseline, and the DRAM effect of
//! sweeping group size and cache capacity.

use tlv_hgnn::datasets::Dataset;
use tlv_hgnn::grouping::{
    default_n_max, group_overlap_driven, simulate_grouper, GrouperConfig, OverlapHypergraph,
};
use tlv_hgnn::model::{ModelConfig, ModelKind};
use tlv_hgnn::sim::{AccelConfig, ExecMode, Simulator};
use tlv_hgnn::util::table::{f2, pct, Table};

fn main() {
    let d = Dataset::Am;
    let g = d.load(0.05);
    let targets = g.target_vertices().len();

    let h = OverlapHypergraph::build(&g, 0.01);
    println!("hypergraph: {} super-vertices (top 15%), {} low-degree rest", h.num_supers(), h.rest.len());
    println!("total overlap weight: {:.1}\n", h.total_weight);

    let mut t = Table::new(&["n_max", "groups", "intra_weight", "grouper_kcycles", "sim_dram_O", "sim_dram_P"]);
    for div in [2usize, 4, 8, 16] {
        let n_max = default_n_max(targets, div);
        let grouping = group_overlap_driven(&h, n_max, 4);
        let gs = simulate_grouper(&h, n_max, &GrouperConfig::default());
        // Channel count fixed at 4; n_max sweeps group granularity.
        let cfg = AccelConfig { channels: 4, ..AccelConfig::tlv_default() };
        let sim = Simulator::new(cfg, &g, ModelConfig::new(ModelKind::Rgcn));
        let o = sim.run(ExecMode::OverlapGrouped);
        let p = sim.run(ExecMode::RandomGrouped);
        t.row(&[
            n_max.to_string(),
            grouping.groups.len().to_string(),
            pct(grouping.intra_weight_fraction),
            (gs.cycles / 1000).to_string(),
            o.dram.accesses.to_string(),
            p.dram.accesses.to_string(),
        ]);
    }
    println!("{}", t.render());

    // Cache sensitivity: grouping matters more as the cache shrinks.
    let mut t2 = Table::new(&["cache", "dram_O", "dram_P", "O_saving"]);
    for mb in [1u64, 2, 4, 6, 12] {
        let cfg = AccelConfig {
            global_cache_bytes: mb * 1024 * 1024 * 2 / 3,
            local_cache_bytes: mb * 1024 * 1024 / 3 / 4,
            ..AccelConfig::tlv_default()
        };
        let sim = Simulator::new(cfg, &g, ModelConfig::new(ModelKind::Rgcn));
        let o = sim.run(ExecMode::OverlapGrouped);
        let p = sim.run(ExecMode::RandomGrouped);
        t2.row(&[
            format!("{mb} MB"),
            o.dram.accesses.to_string(),
            p.dram.accesses.to_string(),
            f2(p.dram.accesses as f64 / o.dram.accesses as f64),
        ]);
    }
    println!("=== Cache-capacity sensitivity (AM@0.05, RGCN) ===");
    println!("{}", t2.render());
}
