//! Quickstart: load a dataset, run the TLV-HGNN simulator in its full
//! configuration (-O), and print the headline metrics.
//!
//!     cargo run --release --example quickstart

use tlv_hgnn::datasets::Dataset;
use tlv_hgnn::energy::{chip_area_mm2, chip_power_w, tlv_energy, EnergyTable};
use tlv_hgnn::hetgraph::stats;
use tlv_hgnn::model::{ModelConfig, ModelKind};
use tlv_hgnn::sim::{AccelConfig, ExecMode, Simulator};
use tlv_hgnn::util::table::{human_bytes, human_count};

fn main() {
    let dataset = Dataset::Acm;
    let g = dataset.load(dataset.bench_scale());
    let s = stats::compute(&g);
    println!("dataset {} — {} vertices, {} edges, {} semantics", s.name, s.vertices, s.edges, s.semantics);
    println!("  redundant feature accesses: {:.1}%", s.redundant_access_fraction * 100.0);
    println!("  top-15% targets hold {:.1}% of edges\n", s.top15_edge_share * 100.0);

    let cfg = AccelConfig::tlv_default();
    println!(
        "TLV-HGNN: {} channels x {} RPEs, {:.2} TFLOPS peak, {:.2} mm^2, {:.2} W",
        cfg.channels,
        cfg.rpes_per_channel,
        cfg.peak_tflops(),
        chip_area_mm2(&cfg),
        chip_power_w(&cfg)
    );

    let m = ModelConfig::new(ModelKind::Rgcn);
    let sim = Simulator::new(cfg.clone(), &g, m.clone());
    let r = sim.run(ExecMode::OverlapGrouped);
    let e = tlv_energy(&r, &cfg, &m, &EnergyTable::default());
    println!("\nRGCN inference (semantics-complete, overlap-grouped):");
    println!("  cycles            {}", human_count(r.cycles));
    println!("  wall @1GHz        {:.3} ms", r.time_ms(&cfg));
    println!("  DRAM accesses     {}", human_count(r.dram.accesses));
    println!("  DRAM traffic      {}", human_bytes(r.dram.bytes));
    println!("  cache hit rate    {:.1}%", r.cache_hit_rate() * 100.0);
    println!("  energy            {:.2} mJ ({:.0}% DRAM)", e.total_mj(), e.dram_fraction() * 100.0);
}
